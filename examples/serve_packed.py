"""Batched serving with lane-packed W4 weights (the paper's packing on
the TPU memory roofline): prefill a batch of prompts, then decode with
the quantized packed parameter tree; compares tokens/s and weight bytes
vs the bf16 baseline.

Run:  PYTHONPATH=src python examples/serve_packed.py
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS
from repro.models import (decode_step, init_cache, init_params,
                          serve_params, values, Rules)
from repro.models.quantized import PackedLinear


def tree_bytes(tree):
    tot = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        tot += leaf.size * leaf.dtype.itemsize
    return tot


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()   # CPU-sized backbone of the family
    rules = Rules(tp=None, fsdp=None, ep=None, batch=())
    params = values(init_params(cfg, rules, jax.random.PRNGKey(0)))
    qparams = serve_params(params, bits=4, min_size=1024)
    b_bf16 = tree_bytes(params)
    b_q = tree_bytes(qparams)
    print(f"weights: bf16 {b_bf16/2**20:.2f} MiB -> packed W4 "
          f"{b_q/2**20:.2f} MiB ({b_bf16/b_q:.2f}x smaller HBM residency)")

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
        dtype=jnp.int32)

    smax = args.prompt_len + args.new_tokens
    dec = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))

    def generate(ptree, label):
        cache = values(init_cache(cfg, rules, args.batch, smax))
        # prefill: teacher-force the prompt through decode steps (keeps
        # the example simple; launch/serve.py shows bulk prefill)
        tok = prompts[:, :1]
        t0 = time.perf_counter()
        outs = []
        for i in range(smax - 1):
            logits, cache = dec(ptree, cache, tok)
            if i + 1 < args.prompt_len:
                tok = prompts[:, i + 1:i + 2]
            else:
                tok = jnp.argmax(logits[:, -1:, :cfg.vocab], axis=-1
                                 ).astype(jnp.int32)
                outs.append(np.asarray(tok)[:, 0])
        dt = time.perf_counter() - t0
        toks = args.batch * (smax - 1)
        print(f"{label}: {toks/dt:8.1f} tok/s  (greedy tail: "
              f"{np.stack(outs, 1)[0][:8]})")
        return np.stack(outs, 1)

    out_q = generate(qparams, "packed W4")
    out_f = generate(params, "bf16     ")
    # random-init logits are near-uniform, so greedy tokens are not a
    # meaningful agreement metric; compare the logit surfaces instead
    lq, _ = decode_step(cfg, qparams,
                        values(init_cache(cfg, rules, args.batch, smax)),
                        prompts[:, :1])
    lf, _ = decode_step(cfg, params,
                        values(init_cache(cfg, rules, args.batch, smax)),
                        prompts[:, :1])
    mae = float(jnp.mean(jnp.abs(lq - lf)))
    rng_sp = float(jnp.abs(lf).max())
    print(f"logit MAE packed-vs-bf16: {mae:.4f} (range ±{rng_sp:.2f})")


if __name__ == "__main__":
    main()
