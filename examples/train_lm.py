"""End-to-end training driver: train a ~100M-param llama-family model
for a few hundred steps on CPU with the full production substrate —
deterministic data, AdamW + cosine, microbatching, async fault-tolerant
checkpointing, straggler monitor, SIGTERM emergency save, resume.

Run:   PYTHONPATH=src python examples/train_lm.py --steps 300
Kill/resume:  Ctrl-C (or SIGTERM), then re-run with --resume.
"""
import argparse
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data import SyntheticLMData
from repro.models import init_params, values, Rules
from repro.train import checkpoint, loop, optimizer, straggler

# ~100M params: 12L x 768 with a 32k vocab
CFG = ArchConfig(name="demo-100m", family="dense", n_layers=12,
                 d_model=768, n_heads=12, n_kv=4, d_ff=2048,
                 vocab=32000, attn_chunk=128)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--small", action="store_true",
                    help="smoke-size model (CI)")
    args = ap.parse_args()

    cfg = CFG.reduced() if args.small else CFG
    rules = Rules(tp=None, fsdp=None, ep=None, batch=())
    params = values(init_params(cfg, rules, jax.random.PRNGKey(0)))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

    ocfg = optimizer.OptConfig(lr=3e-4, warmup=20, total_steps=args.steps)
    opt = optimizer.init(ocfg, params)
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch, seed=0)
    step_fn = jax.jit(loop.make_train_step(cfg, ocfg, microbatches=2))

    start = 0
    if args.resume:
        last = checkpoint.latest_step(args.ckpt_dir)
        if last is not None:
            (params, opt), meta = checkpoint.restore(
                args.ckpt_dir, last, (params, opt))
            start = meta["step"]
            print(f"resumed from step {start}")

    ck = checkpoint.AsyncCheckpointer(args.ckpt_dir, keep=3)
    mon = straggler.StepMonitor()
    state = {"params": params, "opt": opt, "step": start}

    def flush():
        ck.wait()
        checkpoint.save(args.ckpt_dir, state["step"],
                        (state["params"], state["opt"]))
        print(f"\nemergency checkpoint at step {state['step']}")

    checkpoint.install_sigterm_handler(flush)

    for s in range(start, args.steps):
        batch = data.device_batch(s)          # pure function of (seed, s)
        mon.start()
        params, opt, m = step_fn(params, opt, batch)
        dt = mon.stop()
        state.update(params=params, opt=opt, step=s + 1)
        if mon.should_mitigate:
            print(f"[straggler] sustained slow steps "
                  f"(ema {mon.ema:.3f}s) — a fleet driver would "
                  f"checkpoint + rebalance here")
        if (s + 1) % args.ckpt_every == 0 or s + 1 == args.steps:
            ck.save_async(s + 1, (params, opt))
        if (s + 1) % 20 == 0 or s == start:
            print(f"step {s+1:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"|g| {float(m['grad_norm']):.3f}  {dt*1e3:.0f} ms")
    ck.wait()
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
